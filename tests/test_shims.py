"""The deprecated engine facades: each legacy class warns exactly once at
construction and still routes bitwise through the unified engine core.

This module runs with ``DeprecationWarning`` promoted to an error: the
*expected* shim warnings are captured by ``pytest.warns``, so any *new*
DeprecationWarning — from the shims themselves, from the engine core they
delegate to, or from a jax API the refactor started leaning on — fails CI
(see the multidevice job's deprecation gate).
"""

import numpy as np
import pytest

from repro.configs import ScenarioBatch
from repro.core import disease, simulator, simulator_dist, transmission
from repro.data import digital_twin_population
from repro.engine import EngineCore
from repro.launch.mesh import make_hybrid_mesh, make_scenario_mesh, make_worker_mesh
from repro.sweep import EnsembleSimulator, HybridEnsemble, ShardedEnsemble

pytestmark = pytest.mark.filterwarnings("error::DeprecationWarning")

DAYS = 6


@pytest.fixture(scope="module")
def pop():
    return digital_twin_population(700, seed=9, name="shim-t")


@pytest.fixture(scope="module")
def batch():
    return ScenarioBatch.from_product(
        disease=disease.covid_model(), tau=2e-5, seeds=[3, 4])


def _core_hist(pop, batch):
    core = EngineCore(pop, batch, layout="local")
    return core.run_days(DAYS)[2]


def test_engine_core_does_not_warn(pop, batch):
    # DeprecationWarning is an *error* in this module: if the core itself
    # (or anything it delegates to) starts warning, this raises.
    _core_hist(pop, batch)


def test_epidemic_simulator_shim(pop, batch):
    ref = _core_hist(pop, batch)
    s = batch[0]
    with pytest.warns(DeprecationWarning, match="EpidemicSimulator"):
        sim = simulator.EpidemicSimulator(
            pop, s.disease, s.tm, interventions=s.interventions, seed=s.seed)
    _, h = sim.run(DAYS)
    np.testing.assert_array_equal(h["cumulative"], ref["cumulative"][:, 0])


def test_ensemble_simulator_shim(pop, batch):
    ref = _core_hist(pop, batch)
    with pytest.warns(DeprecationWarning, match="EnsembleSimulator"):
        ens = EnsembleSimulator(pop, batch)
    _, h = ens.run(DAYS)
    np.testing.assert_array_equal(h["cumulative"], ref["cumulative"])


def test_dist_simulator_shim(pop, batch):
    ref = _core_hist(pop, batch)
    s = batch[0]
    with pytest.warns(DeprecationWarning, match="DistSimulator"):
        d = simulator_dist.DistSimulator(
            pop, s.disease, make_worker_mesh(1),
            transmission.TransmissionModel(tau=s.tm.tau), seed=s.seed)
    _, h = d.run(DAYS)
    np.testing.assert_array_equal(h["cumulative"], ref["cumulative"][:, 0])


def test_sharded_ensemble_shim(pop, batch):
    ref = _core_hist(pop, batch)
    with pytest.warns(DeprecationWarning, match="ShardedEnsemble"):
        ens = ShardedEnsemble(pop, batch, mesh=make_scenario_mesh(1))
    _, h = ens.run(DAYS)
    np.testing.assert_array_equal(h["cumulative"], ref["cumulative"])


def test_hybrid_ensemble_shim(pop, batch):
    ref = _core_hist(pop, batch)
    with pytest.warns(DeprecationWarning, match="HybridEnsemble"):
        ens = HybridEnsemble(pop, batch, mesh=make_hybrid_mesh(1, 1))
    _, h = ens.run(DAYS)
    np.testing.assert_array_equal(h["cumulative"], ref["cumulative"])
