"""EpiHiper-style static-network baseline: the independent edge-list SIR
implementation agrees with the simulator's static_network mode."""

import numpy as np
import pytest

from repro.core import baseline, disease, transmission
from repro.data import watts_strogatz_population


@pytest.fixture(scope="module")
def pop():
    return watts_strogatz_population(500, 120, seed=9, name="bl")


def test_network_precompute_symmetric(pop):
    net = baseline.precompute_contact_network(pop, seed=4)
    for dow in range(7):
        assert len(net.src[dow]) == len(net.dst[dow]) == len(net.duration[dow])
        assert (net.duration[dow] > 0).all()


def test_static_mode_matches_edge_list_oracle(pop):
    """The simulator with static_network=True must produce the same
    epidemic as explicit diffusion over the precomputed network (same
    seeds, same transmission model)."""
    tm = transmission.TransmissionModel(tau=1.5e-5)
    days, seed = 30, 4
    from repro.engine.core import EngineCore
    sim = EngineCore.single(
        pop, disease.sir_model(7.0), tm, seed=seed, static_network=True,
        seed_per_day=2, seed_days=5,
    )
    _, hist = sim.run1(days)
    net = baseline.precompute_contact_network(pop, seed=seed)
    hist_ref = baseline.run_sir_on_network(
        pop, net, tm, days, seed, seed_per_day=2, seed_days=5,
        recovery_days=7.0,
    )
    np.testing.assert_array_equal(hist["cumulative"], hist_ref["cumulative"])
    np.testing.assert_array_equal(hist["infectious"], hist_ref["infectious"])
