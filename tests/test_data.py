import numpy as np

from repro.core import population as pop_lib
from repro.data import (
    digital_twin_population,
    grid_population,
    watts_strogatz_population,
)


def test_ws_population_counts():
    pop = watts_strogatz_population(2000, 500, seed=0)
    assert pop.num_people == 2000
    assert pop.num_locations == 500
    # paper: 5-7 visits per person per day
    for d in pop.week:
        per_person = d.num_real / pop.num_people
        assert 4.9 <= per_person <= 7.1
    # visits sorted by location
    for d in pop.week:
        loc = d.loc[: d.num_real]
        assert (np.diff(loc) >= 0).all()


def test_ws_home_assignment_min_one():
    pop = watts_strogatz_population(300, 200, seed=1)
    counts = np.bincount(pop.home_loc, minlength=200)
    assert counts.sum() == 300
    assert (counts >= 1).all()


def test_grid_population():
    pop = grid_population(20, 20, density=3.0, seed=0)
    assert pop.num_locations == 400
    assert pop.num_people == 1200
    stats = pop.stats()
    assert 3.0 < stats["mean_visits_per_person_day"] < 7.0  # ~lambda 5.2


def test_twin_structure():
    pop = digital_twin_population(3000, seed=0)
    assert pop.num_people == 3000
    assert set(np.unique(pop.loc_type)) <= {0, 1, 2, 3}
    # geo keys sorted by hierarchy give contiguous partitions
    assert pop.geo_key.min() >= 0
    # weekday visits exceed weekend visits (work+school structure)
    weekday = pop.week[0].num_real
    weekend = pop.week[6].num_real
    assert weekday > weekend


def test_balanced_partition_better_than_naive():
    pop = digital_twin_population(4000, seed=1)
    visits = np.zeros(pop.num_locations, np.int64)
    for d in pop.week:
        np.add.at(visits, d.loc[: d.num_real], 1)
    W = 16
    bal = pop_lib.balanced_location_partition(pop.geo_key, visits, W)
    naive = pop_lib.naive_location_partition(pop.num_locations, W)
    imb_b = pop_lib.partition_imbalance(bal, visits, W)
    imb_n = pop_lib.partition_imbalance(naive, visits, W)
    assert imb_b < imb_n
    assert imb_b < 1.6  # near-balanced


def test_pack_day_padding():
    d = pop_lib.pack_day(
        np.array([3, 1]), np.array([5, 2]),
        np.array([1.0, 2.0], np.float32), np.array([9.0, 8.0], np.float32),
        pad_multiple=128,
    )
    assert len(d) == 128
    assert d.num_real == 2
    assert (d.person[2:] == -1).all()
    assert not d.active[2:].any()
    assert (np.diff(d.loc[:2]) >= 0).all()


def test_preprocess_records_packing_stats():
    pop = digital_twin_population(600, seed=4, name="prep")
    stats = pop.preprocess(block_size=64)
    assert stats is pop.preprocess_stats
    pk = stats["packing"]
    assert pk["block_size"] == 64
    assert 0 < pk["np_after"] <= pk["np_before"]
    assert pk["np_reduction"] >= 1.0
    # contact model was (re)finalized as part of preprocessing
    assert (pop.contact_prob > 0).all() and (pop.contact_prob <= 1).all()


def test_occupancy_packing_giant_alignment():
    """A giant location preceded by a small one gets block-aligned, so its
    band does not absorb the small run's block."""
    b = 32
    n_small, n_giant = 10, 3 * b
    person = np.arange(n_small + n_giant)
    loc = np.concatenate([np.zeros(n_small, np.int64),
                          np.ones(n_giant, np.int64)])
    start = np.zeros(n_small + n_giant, np.float32)
    end = np.full(n_small + n_giant, 10.0, np.float32)
    day = pop_lib.pack_day(person, loc, start, end, pad_multiple=b)
    sched_u = pop_lib.build_block_schedule(day.loc, day.num_real, b)
    packed = pop_lib.pack_day_occupancy(day, b)
    sched_p = pop_lib.build_block_schedule(packed.loc, packed.extent, b)
    # unpacked: giant straddles 4 blocks -> 16 tiles + small's 1 (shared);
    # packed: giant exactly 3 blocks (9 tiles) + small's own block (1).
    assert sched_p.num_pairs == 10
    assert sched_u.num_pairs > sched_p.num_pairs
    # giant run starts on a block boundary
    giant_slots = np.flatnonzero(
        (packed.person >= 0) & (packed.loc == 1)
    )
    assert giant_slots[0] % b == 0
