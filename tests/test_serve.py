"""The serving tier (repro.serve): bucket normalization, scenario-axis
request batching, and the two contracts that make it safe to use —
served results bitwise-equal to solo ``api.run`` (including observables,
across padding amounts and batch companions), and zero steady-state
recompiles after warmup (sentinel-backed, surfaced in metrics)."""

import json
import threading
import urllib.error
import urllib.request

import numpy as np
import pytest
import jax

from repro import api
from repro.configs import get_epidemic
from repro.serve import (
    RequestBatcher,
    ServeConfig,
    ServeError,
    ServeRequest,
    SimulationServer,
    bucketize,
    quantize_up,
)


@pytest.fixture(scope="module")
def pop():
    return get_epidemic("twin-2k").build()


def _spec(**kw):
    base = dict(dataset="twin-2k", days=6, tau=2e-5,
                interventions=("none", "school-closure"), replicates=1)
    base.update(kw)
    return api.ExperimentSpec(**base).validate()


def _server(pop, **cfg):
    """A server with the test population pre-seeded so every test shares
    one build."""
    server = SimulationServer(ServeConfig(**cfg))
    server._pops["twin-2k"] = pop
    return server


def _assert_result_equal(solo, served):
    """Bitwise equality of everything a client consumes. Provenance is
    deliberately different (that is the point of ``served_from``)."""
    assert solo.scenario_names == served.scenario_names
    assert set(solo.history) == set(served.history)
    for k in solo.history:
        np.testing.assert_array_equal(solo.history[k], served.history[k],
                                      err_msg=f"history[{k}]")
    eq = jax.tree.map(
        lambda a, b: bool(np.array_equal(np.asarray(a), np.asarray(b))),
        solo.observables, served.observables)
    assert all(jax.tree.leaves(eq)), f"observable mismatch: {eq}"
    assert solo.summaries == served.summaries


# ---------------------------------------------------------------------------
# bucket normalization
# ---------------------------------------------------------------------------


def test_quantize_up_lattice():
    assert quantize_up(1, (4, 8)) == 4
    assert quantize_up(4, (4, 8)) == 4
    assert quantize_up(5, (4, 8)) == 8
    # beyond the lattice: next power of two, stable across nearby sizes
    assert quantize_up(9, (4, 8)) == 16
    assert quantize_up(16, (4, 8)) == 16
    with pytest.raises(ValueError):
        quantize_up(0, (4,))


def test_bucketize_traced_values_share_buckets():
    cfg = ServeConfig()
    a = bucketize(_spec(seed=1), cfg)
    b = bucketize(_spec(seed=99, tau=3e-5, replicates=2), cfg)
    # seeds/tau are traced, replicates 1->2 stays under the width floor
    assert a.bucket == b.bucket
    assert a.b_request == 2 and b.b_request == 4
    # days is dispatch grouping, NOT executable identity
    c = bucketize(_spec(days=40), cfg)
    assert c.bucket == a.bucket
    assert c.n_chunks != a.n_chunks
    # the interventions *tuple* is executable identity (slot structure)
    d = bucketize(_spec(interventions=("none",)), cfg)
    assert d.bucket != a.bucket


def test_bucketize_refuses_unservable_specs(pop):
    server = _server(pop)
    with pytest.raises(ValueError, match="checkpoint"):
        server.submit(_spec(
            checkpoint=api.CheckpointSpec(directory="/tmp/nope")))
    with pytest.raises(ValueError, match="engine"):
        server.submit(_spec(engine="ensemble"))
    assert server.metrics_dict()["requests"]["rejected"] == 2


# ---------------------------------------------------------------------------
# batcher grouping
# ---------------------------------------------------------------------------


def _req(shape_spec, cfg):
    spec = shape_spec.validate()
    return ServeRequest(spec, bucketize(spec, cfg))


def test_batcher_groups_fifo_by_shape_and_capacity():
    cfg = ServeConfig(b_lattice=(4,))
    batcher = RequestBatcher()
    r1 = _req(_spec(seed=1), cfg)            # B=2
    r2 = _req(_spec(seed=2), cfg)            # B=2, same bucket -> joins
    r3 = _req(_spec(seed=3, replicates=2), cfg)  # B=4, no room -> next group
    r4 = _req(_spec(seed=4, days=40), cfg)   # other chunk count -> own group
    for r in (r1, r2, r3, r4):
        batcher.add(r)
    assert batcher.take_group() == [r1, r2]
    assert batcher.take_group() == [r3]
    assert batcher.take_group() == [r4]
    assert batcher.take_group() == []


# ---------------------------------------------------------------------------
# the bitwise contract
# ---------------------------------------------------------------------------


def test_served_bitwise_equals_solo_run(pop):
    spec = _spec(seed=5)
    solo = api.run(spec, population=pop)
    server = _server(pop, chunk_days=4, b_lattice=(4,))
    served = server.run(spec)
    _assert_result_equal(solo, served)
    sf = served.served_from
    assert sf["b_bucket"] == 4 and sf["slots"] == 2  # 2 real + 2 no-op pad
    assert sf["padded_days"] == 8 and spec.days == 6  # trimmed prefix
    assert solo.served_from is None


def test_served_bitwise_across_padding_amounts(pop):
    """The same spec through buckets of different widths (different no-op
    padding) and chunk sizes: all bitwise-identical to the solo run."""
    spec = _spec(seed=6)
    solo = api.run(spec, population=pop)
    for b_lattice, chunk_days in (((2,), 3), ((4,), 2), ((8,), 6)):
        server = _server(pop, chunk_days=chunk_days, b_lattice=b_lattice)
        served = server.run(spec)
        assert served.served_from["b_bucket"] == b_lattice[0]
        _assert_result_equal(solo, served)


def test_batched_mixed_requests_bitwise(pop):
    """Concurrent heterogeneous requests share one dispatch (one compiled
    program, packed scenario slots) and each comes back bitwise-equal to
    its solo run."""
    s1 = _spec(seed=11)
    s2 = _spec(seed=42, tau=2.6e-5, replicates=2)  # B=4, traced values vary
    solo1 = api.run(s1, population=pop)
    solo2 = api.run(s2, population=pop)
    server = _server(pop, chunk_days=3, b_lattice=(8,))
    t1, t2 = server.submit(s1), server.submit(s2)
    server.drain()
    r1, r2 = t1.result(timeout=60), t2.result(timeout=60)
    # one shared batch: both requests, adjacent slots, 2 pad slots
    assert r1.served_from["batch_requests"] == 2
    assert r2.served_from["batch_requests"] == 2
    assert r1.served_from["slot_offset"] == 0
    assert r2.served_from["slot_offset"] == 2
    assert server.metrics_dict()["batches"]["dispatched"] == 1
    _assert_result_equal(solo1, r1)
    _assert_result_equal(solo2, r2)


def test_streaming_chunks_match_final_history(pop):
    spec = _spec(seed=7, days=7)
    server = _server(pop, chunk_days=3, b_lattice=(2,))
    ticket = server.submit(spec)
    server.drain()
    chunks = list(ticket.stream(timeout=60))
    result = ticket.result(timeout=60)
    assert [c["day_start"] for c in chunks] == [0, 3, 6]
    assert sum(c["days"] for c in chunks) == spec.days  # trimmed last chunk
    for c in chunks:
        lo, hi = c["day_start"], c["day_start"] + c["days"]
        for k, v in c["stats"].items():
            np.testing.assert_array_equal(v, result.history[k][lo:hi])


# ---------------------------------------------------------------------------
# zero-recompile steady state + executable budget
# ---------------------------------------------------------------------------


def test_zero_recompiles_after_warmup(pop):
    server = _server(pop, chunk_days=3, b_lattice=(4,))
    info = server.warm_up(_spec())
    assert not info["already_warm"]
    assert server.warm_up(_spec(seed=9))["already_warm"]
    # a varied request mix: seeds, tau, replicate widths, day counts
    for i, s in enumerate([
        _spec(seed=1), _spec(seed=2, tau=3e-5), _spec(seed=3, replicates=2),
        _spec(seed=4, days=9), _spec(seed=5, days=3),
    ]):
        served = server.run(s)
        assert served.served_from["warm"], f"request {i} missed the cache"
    ex = server.metrics_dict()["executables"]
    assert ex["recompile_violations"] == 0
    assert ex["cold_compiles"] == 1  # the warmup, nothing else
    assert ex["warm_dispatches"] == 5


def test_bucket_lru_eviction_and_rewarm(pop):
    server = _server(pop, chunk_days=3, b_lattice=(2,), max_executables=1)
    a, b = _spec(seed=1), _spec(seed=2, interventions=("none",))
    server.run(a)  # cold: bucket A
    server.run(b)  # cold: bucket B evicts A
    stats = server.metrics_dict()["buckets"]
    assert stats["table"]["size"] == 1
    assert stats["table"]["evictions"] == 1
    assert len(stats["evicted"]) == 1
    served = server.run(a)  # A must cold-compile again
    assert not served.served_from["warm"]
    assert server.metrics_dict()["executables"]["cold_compiles"] == 3


def test_strict_mode_fails_on_sentinel_trip(pop, monkeypatch):
    """A steady-state recompile is a hard error under strict (the default)
    and a counted-but-served event otherwise."""
    from repro.serve import server as server_mod

    class TrippingSentinel:
        def __init__(self, fn, allow=0):
            pass

        def __enter__(self):
            return self

        def __exit__(self, exc_type, exc, tb):
            if exc_type is None:
                raise AssertionError("recompile sentinel: jit cache grew")
            return False

    monkeypatch.setattr(server_mod.hlo, "recompile_sentinel",
                        TrippingSentinel)
    strict = _server(pop, chunk_days=3, b_lattice=(2,))
    strict.warm_up(_spec())
    with pytest.raises(ServeError, match="recompile"):
        strict.run(_spec(seed=1))
    m = strict.metrics_dict()
    assert m["executables"]["recompile_violations"] == 1
    assert m["requests"]["failed"] == 1

    lax_srv = _server(pop, chunk_days=3, b_lattice=(2,), strict=False)
    lax_srv.warm_up(_spec())
    result = lax_srv.run(_spec(seed=1))  # served anyway, violation counted
    assert result is not None
    assert lax_srv.metrics_dict()["executables"]["recompile_violations"] == 1


def test_background_thread_serving(pop):
    """submit() under a running dispatch thread resolves tickets without
    an explicit drain."""
    server = _server(pop, chunk_days=3, b_lattice=(4,))
    server.warm_up(_spec())
    with server:
        tickets = [server.submit(_spec(seed=i + 1)) for i in range(4)]
        results = [t.result(timeout=120) for t in tickets]
    assert all(r.served_from["warm"] for r in results)
    m = server.metrics_dict()
    assert m["requests"]["completed"] == 4
    assert m["executables"]["recompile_violations"] == 0


# ---------------------------------------------------------------------------
# HTTP front (stdlib)
# ---------------------------------------------------------------------------


def test_http_front_run_and_metrics(pop):
    from repro.launch.serve_sim import make_http_server

    server = _server(pop, chunk_days=3, b_lattice=(2,))
    server.warm_up(_spec())
    httpd = make_http_server(server, 0)  # ephemeral port
    port = httpd.server_address[1]
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()
    server.start()
    try:
        spec = _spec(seed=8)
        solo = api.run(spec, population=pop)
        req = urllib.request.Request(
            f"http://127.0.0.1:{port}/run",
            data=spec.to_json().encode(),
            headers={"Content-Type": "application/json"})
        with urllib.request.urlopen(req, timeout=120) as resp:
            assert resp.status == 200
            payload = json.load(resp)
        served_hist = {k: np.asarray(v)
                       for k, v in payload["history"].items()}
        for k in solo.history:
            np.testing.assert_array_equal(solo.history[k], served_hist[k])
        assert payload["provenance"]["served_from"]["warm"]

        with urllib.request.urlopen(
                f"http://127.0.0.1:{port}/metrics", timeout=30) as resp:
            metrics = json.load(resp)
        assert metrics["requests"]["completed"] == 1
        assert metrics["executables"]["recompile_violations"] == 0

        bad = urllib.request.Request(
            f"http://127.0.0.1:{port}/run",
            data=json.dumps({"dataset": "no-such"}).encode())
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(bad, timeout=30)
        assert ei.value.code == 400
    finally:
        httpd.shutdown()
        httpd.server_close()
        server.stop()
