import numpy as np
import jax.numpy as jnp

from repro.core import rng


def test_deterministic():
    a = rng.uniform(7, rng.CONTACT, 3, jnp.arange(100, dtype=jnp.uint32))
    b = rng.uniform(7, rng.CONTACT, 3, jnp.arange(100, dtype=jnp.uint32))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_independence():
    pid = jnp.arange(1000, dtype=jnp.uint32)
    a = np.asarray(rng.uniform(7, rng.CONTACT, 3, pid))
    b = np.asarray(rng.uniform(7, rng.INFECT, 3, pid))
    assert not np.allclose(a, b)
    assert abs(np.corrcoef(a, b)[0, 1]) < 0.1


def test_np_jnp_match():
    pid = np.arange(4096)
    a = np.asarray(rng.uniform(42, rng.DWELL, 17, jnp.asarray(pid, jnp.uint32)))
    b = rng.np_uniform(42, int(rng.DWELL), 17, pid)
    np.testing.assert_allclose(a, b, atol=1e-6)


def test_uniformity():
    u = np.asarray(rng.uniform(1, rng.SEED_CHOICE, 0, jnp.arange(50_000, dtype=jnp.uint32)))
    assert 0.0 < u.min() and u.max() < 1.0
    hist, _ = np.histogram(u, bins=20, range=(0, 1))
    assert hist.min() > 50_000 / 20 * 0.85
    assert abs(u.mean() - 0.5) < 0.01


def test_order_sensitivity():
    # Literal stream/day/agent ids on purpose: the test's whole point is
    # that permuting the counter words changes the draw.
    a = np.asarray(rng.uniform(1, 2, 3))  # detlint: ignore[DET002]
    b = np.asarray(rng.uniform(1, 3, 2))  # detlint: ignore[DET002]
    assert a != b


def test_exponential_positive():
    e = np.asarray(rng.exponential(5.0, 1, rng.DWELL, 0, jnp.arange(1000, dtype=jnp.uint32)))
    assert (e > 0).all()
    assert abs(e.mean() - 5.0) < 0.5


def test_categorical_distribution():
    cum = jnp.asarray([[0.2, 0.5, 1.0]], jnp.float32)
    idx = rng.categorical(
        jnp.broadcast_to(cum, (20000, 3)), 1, rng.TRANSITION, 0,
        jnp.arange(20000, dtype=jnp.uint32),
    )
    counts = np.bincount(np.asarray(idx), minlength=3) / 20000
    np.testing.assert_allclose(counts, [0.2, 0.3, 0.5], atol=0.02)
