import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (
    AdamWConfig, adamw_init, adamw_update, compress_int8, cosine_schedule,
    decompress_int8, error_feedback_update,
)


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0], jnp.float32)}
    state = adamw_init(params)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state, m = adamw_update(cfg, params, g, state)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(state["step"]) == 200


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.ones(4, jnp.float32)}
    state = adamw_init(params)
    g = {"w": jnp.full((4,), 100.0, jnp.float32)}
    _, _, m = adamw_update(cfg, params, g, state)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_cosine_schedule_shape():
    f = cosine_schedule(10, 100)
    xs = [float(f(jnp.asarray(s))) for s in (0, 5, 10, 50, 100)]
    assert xs[0] == 0.0
    assert xs[1] == pytest.approx(0.5)
    assert xs[2] == pytest.approx(1.0)
    assert xs[3] < 1.0
    assert xs[4] == pytest.approx(0.1, abs=1e-6)


def test_int8_compression_roundtrip():
    g = jax.random.normal(jax.random.key(0), (1000,)) * 0.01
    q, scale = compress_int8(g)
    back = decompress_int8(q, scale)
    assert q.dtype == jnp.int8
    np.testing.assert_allclose(np.asarray(back), np.asarray(g), atol=float(scale))


def test_error_feedback_converges():
    """Residual carrying: the cumulative sum of decompressed grads tracks
    the cumulative sum of true grads to within one quantization step."""
    true_sum = jnp.zeros(64, jnp.float32)
    sent_sum = jnp.zeros(64, jnp.float32)
    res = jnp.zeros(64, jnp.float32)
    for i in range(50):
        g = jax.random.normal(jax.random.key(i), (64,)) * 0.1
        (q, s), res = error_feedback_update(g, res)
        sent_sum = sent_sum + decompress_int8(q, s)
        true_sum = true_sum + g
    err = float(jnp.abs(sent_sum - true_sum).max())
    assert err < 0.01
