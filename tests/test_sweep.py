"""Scenario ensembles on the engine core: vmapped-vs-sequential bitwise
equality and ScenarioBatch broadcasting/stacking round-trips."""

import dataclasses

import numpy as np
import jax
import pytest

from repro.configs import Scenario, ScenarioBatch
from repro.core import disease, simulator
from repro.core import interventions as iv
from repro.data import digital_twin_population
from repro.engine.core import EngineCore, index_params, stack_params


@pytest.fixture(scope="module")
def pop():
    return digital_twin_population(1200, seed=3, name="sweep-t")


def _mc_batch(seeds=(7, 8, 9), tau=1.5e-5):
    return ScenarioBatch.from_product(
        disease=disease.covid_model(), tau=tau, seeds=list(seeds)
    )


# ---------------------------------------------------------------------------
# Bitwise equality: one vmapped scan == B sequential single-scenario runs
# ---------------------------------------------------------------------------


def test_vmapped_ensemble_bitwise_equals_sequential(pop):
    days = 20
    batch = _mc_batch(seeds=(7, 8, 9))
    ens = EngineCore(pop, batch)
    final, hist = ens.run(days)
    assert hist["cumulative"].shape == (days, 3)

    for i, s in enumerate(batch):
        sim = EngineCore.single(
            pop, s.disease, s.tm, interventions=s.interventions, seed=s.seed
        )
        f1, h1 = sim.run1(days)
        for key in ("cumulative", "new_infections", "infectious",
                    "susceptible", "contacts"):
            np.testing.assert_array_equal(h1[key], hist[key][:, i])
        np.testing.assert_array_equal(
            np.asarray(f1.health), np.asarray(final.health)[i]
        )
        np.testing.assert_array_equal(
            np.asarray(f1.dwell), np.asarray(final.dwell)[i]
        )


def test_intervention_cells_bitwise_equal_sequential(pop):
    """Factorial cells (union slots + enabled masks) also match sequential
    runs configured with the same union layout."""
    days = 15
    batch = ScenarioBatch.from_product(
        interventions={
            "baseline": (),
            "schools": [iv.Intervention(
                "schools", iv.CaseThreshold(on=40), iv.LocTypeIs(2),
                iv.CloseLocations(),
            )],
        },
        tau=2e-5,
        seeds=[5],
    )
    ens = EngineCore(pop, batch)
    _, hist = ens.run(days)
    for i, s in enumerate(batch):
        sim = EngineCore.single(
            pop, s.disease, s.tm, interventions=s.interventions,
            seed=s.seed, iv_enabled=s.iv_enabled,
        )
        _, h1 = sim.run1(days)
        np.testing.assert_array_equal(h1["cumulative"], hist["cumulative"][:, i])

    # ...and a disabled slot is an exact no-op vs having no slot at all.
    s0 = batch[0]
    plain = EngineCore.single(
        pop, s0.disease, s0.tm, interventions=(), seed=s0.seed
    )
    _, hp = plain.run1(days)
    np.testing.assert_array_equal(hp["cumulative"], hist["cumulative"][:, 0])


def test_disease_perturbation_axis(pop):
    """Same FSA shape, perturbed tables — runs in one batch and changes
    outcomes."""
    fast = disease.covid_model()
    slow = dataclasses.replace(
        fast, name="covid-slow",
        infectivity=(np.asarray(fast.infectivity) * 0.5).astype(np.float32),
    )
    batch = ScenarioBatch.from_product(
        disease={"fast": fast, "slow": slow}, tau=2e-5, seeds=[1],
    )
    ens = EngineCore(pop, batch)
    _, hist = ens.run(15)
    assert hist["cumulative"][-1, 0] > hist["cumulative"][-1, 1]


def test_ensemble_compact_backend_bitwise_equals_jnp(pop):
    """The active-set backend under vmap: same trajectories as jnp, and the
    vmapped ensemble still matches sequential runs using it."""
    days = 12
    batch = _mc_batch(seeds=(7, 8))
    h_jnp = EngineCore(pop, batch, backend="jnp").run(days)[1]
    h_cpt = EngineCore(pop, batch, backend="compact").run(days)[1]
    for key in ("cumulative", "contacts", "new_infections"):
        np.testing.assert_array_equal(h_jnp[key], h_cpt[key])
    for i, s in enumerate(batch):
        sim = EngineCore.single(
            pop, s.disease, s.tm, interventions=s.interventions, seed=s.seed,
            backend="compact",
        )
        _, h1 = sim.run1(days)
        np.testing.assert_array_equal(h1["cumulative"],
                                      h_cpt["cumulative"][:, i])


# ---------------------------------------------------------------------------
# ScenarioBatch broadcasting / stacking round-trips
# ---------------------------------------------------------------------------


def test_from_product_broadcasting_shape_and_order():
    batch = ScenarioBatch.from_product(
        interventions={"baseline": (), "iso": [iv.Intervention(
            "iso", iv.DayRange(5), iv.Everyone(), iv.Isolate())]},
        tau=[1e-5, 2e-5],
        seeds=[0, 1, 2],
    )
    assert len(batch) == 2 * 2 * 3
    # seeds innermost: first three cells are replicates of the same design
    assert [s.seed for s in batch][:3] == [0, 1, 2]
    assert batch[0].tm.tau == pytest.approx(1e-5)
    # scalar axes broadcast: every scenario shares the union slot list
    assert all(len(s.interventions) == 1 for s in batch)
    assert batch.names[0] == "baseline/tau=1e-05/s0"
    # enabled masks select the cell's own slots
    assert batch[0].iv_enabled == (False,)
    assert batch[-1].iv_enabled == (True,)


def test_params_stack_index_roundtrip(pop):
    batch = _mc_batch(seeds=(3, 4), tau=[1e-5, 3e-5])
    ens = EngineCore(pop, batch)
    for i, s in enumerate(batch):
        *_, single = simulator.build_params(
            pop, s.disease, s.tm, s.interventions, s.seed,
            seed_per_day=s.seed_per_day, seed_days=s.seed_days,
            static_network=s.static_network, iv_enabled=s.iv_enabled,
        )
        sliced = ens.scenario_params(i)
        for a, b in zip(jax.tree.leaves(sliced), jax.tree.leaves(single)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # stack(index(i) for all i) reproduces the batched tree exactly
    restacked = stack_params([index_params(ens.params, i)
                              for i in range(len(batch))])
    for a, b in zip(jax.tree.leaves(restacked), jax.tree.leaves(ens.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Hybrid (workers x scenarios) ensemble — in-process when >= 4 devices (the
# CI multi-device job); the subprocess three-way test lives in test_dist.py.
# ---------------------------------------------------------------------------


def test_hybrid_ensemble_three_way_bitwise(pop):
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    from jax.sharding import Mesh
    from repro.launch.mesh import make_hybrid_mesh

    days = 12
    batch = ScenarioBatch.from_product(
        interventions={
            "baseline": (),
            "schools": [iv.Intervention(
                "schools", iv.CaseThreshold(on=30), iv.LocTypeIs(2),
                iv.CloseLocations(),
            )],
        },
        tau=2e-5,
        seeds=[7],
    )
    hyb = EngineCore(pop, batch, layout="hybrid", mesh=make_hybrid_mesh(2, 2))
    fh, hh = hyb.run(days)

    # vs the single-device vmap ensemble: every stat + final state, bitwise.
    ens = EngineCore(pop, batch)
    fe, he = ens.run(days)
    for key in ("cumulative", "new_infections", "infectious", "susceptible",
                "contacts"):
        np.testing.assert_array_equal(hh[key], he[key])
    np.testing.assert_array_equal(
        np.asarray(fh.health)[:, : pop.num_people], np.asarray(fe.health)
    )
    np.testing.assert_array_equal(
        np.asarray(fh.dwell)[:, : pop.num_people], np.asarray(fe.dwell)
    )

    # vs sequential worker-sharded (layout="workers") runs, bitwise.
    mesh_w = Mesh(np.array(jax.devices()[:2]), ("workers",))
    for i, s in enumerate(batch):
        d = EngineCore.single(
            pop, s.disease, s.tm, interventions=s.interventions,
            seed=s.seed, iv_enabled=s.iv_enabled,
            layout="workers", mesh=mesh_w,
        )
        fd, hd = d.run1(days)
        np.testing.assert_array_equal(hd["cumulative"], hh["cumulative"][:, i])
        np.testing.assert_array_equal(
            np.asarray(fd.health), np.asarray(fh.health)[i]
        )
    # Scenarios genuinely diverge (the closure slot fired in scenario 1).
    assert hh["cumulative"][-1, 0] != hh["cumulative"][-1, 1]


def test_hybrid_batch_padding(pop):
    """A 3-scenario batch on a scenarios-axis of 2 pads to 4 and drops the
    pad from results."""
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 devices "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    from repro.launch.mesh import make_hybrid_mesh

    batch = _mc_batch(seeds=(7, 8, 9))
    hyb = EngineCore(pop, batch, layout="hybrid", mesh=make_hybrid_mesh(2, 2))
    assert len(hyb.padded) == 4
    fh, hh = hyb.run(8)
    assert hh["cumulative"].shape == (8, 3)
    ens = EngineCore(pop, batch)
    _, he = ens.run(8)
    np.testing.assert_array_equal(hh["cumulative"], he["cumulative"])


def test_multiple_vaccinate_slots_rejected(pop):
    """One vaccinated flag carries one efficacy — a union with two Vaccinate
    slots would silently mis-apply multipliers, so compile rejects it."""
    batch = ScenarioBatch.from_product(
        interventions={
            "vaxA": [iv.Intervention("vA", iv.DayRange(5),
                                     iv.RandomFraction(0.5, salt=1),
                                     iv.Vaccinate(0.9))],
            "vaxB": [iv.Intervention("vB", iv.DayRange(5),
                                     iv.RandomFraction(0.5, salt=2),
                                     iv.Vaccinate(0.5))],
        },
        tau=2e-5, seeds=[0],
    )
    with pytest.raises(ValueError, match="Vaccinate"):
        EngineCore(pop, batch)


def test_mismatched_structure_rejected(pop):
    covid = disease.covid_model()
    sir = disease.sir_model()
    with pytest.raises(ValueError, match="states"):
        ScenarioBatch.from_scenarios([
            Scenario(name="a", disease=covid),
            Scenario(name="b", disease=sir),
        ])
    with pytest.raises(ValueError, match="slot"):
        ScenarioBatch.from_scenarios([
            Scenario(name="a", disease=covid),
            Scenario(name="b", disease=covid, interventions=(
                iv.Intervention("x", iv.DayRange(0), iv.Everyone(),
                                iv.Isolate()),
            )),
        ])
